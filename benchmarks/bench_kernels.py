"""Kernel micro-benchmarks: Pallas (interpret on CPU hosts) vs reference.

Wall time on this host measures the *reference* path (interpret mode runs
the kernel body in Python and is not a performance number); the TPU-side
story is the modeled VMEM-resident chaining (see bench_dataflow) plus the
kernel's per-shape MXU utilisation from the perf model, reported here as
`derived`.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import perf_model
from repro.kernels import ref


def _time(fn, *args, iters=5) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(print_fn=print) -> list[dict]:
    hw = perf_model.TPU_V5E
    rows = []
    # rank-8 as an OUTPUT dim (m=8) is the MXU-starved case TNN steps hit;
    # rank-8 as the contracted dim (k=8) stays efficient.
    shapes = [("gemm-512", 512, 512, 512), ("gemm-odd", 384, 768, 192),
              ("gemm-rank8-out", 8, 2048, 2048),
              ("gemm-rank8-contract", 2048, 8, 2048)]
    for name, m, k, n in shapes:
        x = jax.random.normal(jax.random.key(0), (m, k), jnp.bfloat16)
        w = jax.random.normal(jax.random.key(1), (k, n), jnp.bfloat16)
        us = _time(lambda a, b: ref.matmul(a, b), x, w) * 1e6
        util = hw.mxu_utilisation(m, n, k)
        rows.append({"name": f"matmul/{name}", "us_per_call": us,
                     "derived": f"mxu_util={util:.3f}"})
    # chain kernel: modeled HBM saving of VMEM-resident intermediate
    x = jax.random.normal(jax.random.key(0), (1024, 256), jnp.bfloat16)
    a = jax.random.normal(jax.random.key(1), (256, 64), jnp.bfloat16)
    b = jax.random.normal(jax.random.key(2), (64, 1024), jnp.bfloat16)
    us = _time(lambda *t: ref.chain(*t), x, a, b) * 1e6
    saved = 1024 * 64 * 2 * 2      # intermediate write+read avoided, bytes
    rows.append({"name": "chain/1024x256x64x1024", "us_per_call": us,
                 "derived": f"hbm_saved_bytes={saved}"})
    # ssm scan: chunked vs sequential oracle speed ratio on host
    bh, t, dk, dv = 4, 512, 32, 64
    q = jax.random.normal(jax.random.key(0), (bh, t, dk)) * 0.5
    k2 = jax.random.normal(jax.random.key(1), (bh, t, dk)) * 0.5
    v = jax.random.normal(jax.random.key(2), (bh, t, dv)) * 0.5
    ld = -jnp.ones((bh, t, dk)) * 0.05
    us_chunk = _time(jax.jit(
        lambda *args: ref.chunked_linear_scan(*args, chunk=128)),
        q, k2, v, ld) * 1e6
    us_seq = _time(jax.jit(ref.linear_scan_batched), q, k2, v, ld) * 1e6
    rows.append({"name": "ssm/chunked-vs-sequential", "us_per_call": us_chunk,
                 "derived": f"speedup={us_seq/us_chunk:.2f}x"})
    for r in rows:
        print_fn(f"{r['name']:28s} {r['us_per_call']:10.1f} us  {r['derived']}")
    return rows


def validate(rows) -> list[str]:
    failures = []
    for r in rows:
        if "rank8-out" in r["name"] and "util" in r["derived"]:
            util = float(r["derived"].split("=")[1])
            if util > 0.2:
                failures.append("rank-8 GEMM should show low MXU util")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
