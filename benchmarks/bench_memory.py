"""Memory benchmark: plan peaks, budgeted CSSE, and the training stash.

Three groups of records, all carrying the schema's ``peak_bytes`` field so
CI's bench-smoke job gates memory regressions alongside wall_s:

* ``memory/plan/...``  — modeled live-tensor peak of the ATIS-TT FP/WG
  plans under bf16 vs fp8 (the policy halves the working set), probed
  through ``repro.memory.probe_plan`` (measured where the device supports
  allocator stats; deterministic live-bytes accounting on CI's CPU).
* ``memory/csse-budget`` — CSSE with ``memory_budget`` set to the tightest
  candidate peak: the winner must fit the budget, trading latency for
  footprint (validated every run).
* ``memory/lm-stash/...`` — the smoke-LM activation stash under the three
  stash policies: ``quantized`` must be >= 2x below ``store`` at the
  planner's microbatch split, and ``recompute`` must undercut both (ISSUE
  acceptance; the e2e loss-parity half lives in ``tests/test_memory.py``).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import memory
from repro.core import contraction, csse, factorizations as F
from repro.core import perf_model as pm
from repro.core import tensorized as tz
from repro.core.tnetwork import plan_from_tree
from repro.precision import QuantPolicy

TOKENS = 128
BUDGET = "96KB"         # training budget for the lm-stash group


def _plan_rows(rows, print_fn):
    fact = F.tt((12, 8, 8), (8, 8, 12), 8)          # ATIS-TT (Table II)
    nets = {
        "fp": fact.forward_network(batch_axes=(("b", TOKENS),)),
        "wg0": tz._wg_network(fact, TOKENS, 0),
    }
    for phase, net in nets.items():
        plan = csse.search(net, csse.SearchOptions(fused_chain=True)).plan
        # bf16 operands so the timed run matches the dtype the bf16 row's
        # modeled peak is priced at (the fp8 row's wall_s stays 0).
        arrays = [(jax.random.normal(jax.random.key(i), net.node_shape(i),
                                     jnp.float32) / 8).astype(jnp.bfloat16)
                  for i in range(net.num_nodes)]
        fn = jax.jit(lambda ts: contraction.execute(plan, ts))
        fn(arrays).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fn(arrays).block_until_ready()
        wall = (time.perf_counter() - t0) / 3
        for pname, pol in (("bf16", None),
                           ("fp8_e4m3", QuantPolicy.parse("fp8_e4m3"))):
            probe = memory.probe_plan(plan, policy=pol)
            rows.append({
                "name": f"memory/plan/ATIS-TT/{phase}/{pname}",
                "wall_s": wall if pname == "bf16" else 0.0,
                "fusion_hit_rate": None,
                "dtype": pname,
                "policy": None if pol is None else pol.tag,
                "peak_bytes": probe.peak_bytes,
                "probe_source": probe.source,
            })
            print_fn(f"{rows[-1]['name']:42s} "
                     f"peak={probe.peak_bytes:>8d}B ({probe.source})")


def _budget_rows(rows, print_fn):
    fact = F.tt((12, 8, 8), (8, 8, 12), 8)
    net = fact.forward_network(batch_axes=(("b", TOKENS),))
    free = csse.search(net, csse.SearchOptions(objective="latency"))
    peaks = sorted(pm.peak_bytes(plan_from_tree(net, t))
                   for _, t in free.candidates)
    tight = peaks[0]
    t0 = time.perf_counter()
    budgeted = csse.search(net, csse.SearchOptions(
        objective="latency", memory_budget=tight))
    search_s = time.perf_counter() - t0
    rows.append({
        # wall_s stays 0 (ungated): the search cost is cold-vs-warm cache
        # dependent; the gated quantity here is the peak, which is exact.
        "name": "memory/csse-budget/ATIS-TT/fp",
        "wall_s": 0.0,
        "search_s": search_s,
        "fusion_hit_rate": None,
        "dtype": None,
        "policy": None,
        "peak_bytes": budgeted.cost.peak_bytes,
        "budget": tight,
        "free_peak_bytes": free.cost.peak_bytes,
        "latency_premium": (budgeted.cost.latency_s
                            / max(free.cost.latency_s, 1e-12)),
    })
    print_fn(f"{rows[-1]['name']:42s} free={free.cost.peak_bytes}B "
             f"budgeted={budgeted.cost.peak_bytes}B (budget {tight}B, "
             f"{rows[-1]['latency_premium']:.2f}x latency)")


def _lm_rows(rows, print_fn):
    from repro.configs import base as cfgbase
    from repro.core.tensorized import TNNConfig

    arch = cfgbase.get("tinyllama_1_1b")
    budget = memory.parse_budget(BUDGET)
    global_batch, seq = 8, 64
    # (name suffix, stash policy, budget) — "quantized-mb1" holds the
    # microbatch count fixed so the pure dtype-halving invariant is gated
    # on its own, separate from the budget-driven accumulation win.
    cases = (("store", "store", None),
             ("recompute", "recompute", budget),
             ("quantized-mb1", "quantized", None),
             ("quantized", "quantized", budget))
    for name, policy, case_budget in cases:
        tnn = TNNConfig(enabled=True, method="tt", rank=8, num_factors=3,
                        targets=("mlp",), remat=policy)
        cfg = arch.smoke(tnn)
        stashp = tnn.stash_policy()
        mb, _ = memory.plan_microbatches(cfg, global_batch, seq,
                                         case_budget, stashp)
        probe = memory.probe_training(cfg, global_batch, seq, mb, stashp)
        rows.append({
            "name": f"memory/lm-stash/{name}",
            "wall_s": 0.0,
            "fusion_hit_rate": None,
            "dtype": None,
            "policy": None,
            "peak_bytes": probe.peak_bytes,
            "microbatches": mb,
            "budget": case_budget,
            "probe_source": probe.source,
        })
        print_fn(f"{rows[-1]['name']:42s} peak={probe.peak_bytes:>8d}B "
                 f"mb={mb} ({probe.source})")


def run(print_fn=print) -> list[dict]:
    rows: list[dict] = []
    _plan_rows(rows, print_fn)
    _budget_rows(rows, print_fn)
    _lm_rows(rows, print_fn)
    return rows


def validate(rows) -> list[str]:
    failures: list[str] = []
    by_name = {r["name"]: r for r in rows}
    for phase in ("fp", "wg0"):
        bf16 = by_name[f"memory/plan/ATIS-TT/{phase}/bf16"]
        fp8 = by_name[f"memory/plan/ATIS-TT/{phase}/fp8_e4m3"]
        if fp8["peak_bytes"] * 2 != bf16["peak_bytes"]:
            failures.append(
                f"memory/plan/{phase}: fp8 peak {fp8['peak_bytes']} is not "
                f"half the bf16 peak {bf16['peak_bytes']}")
    b = by_name["memory/csse-budget/ATIS-TT/fp"]
    if b["peak_bytes"] > b["budget"]:
        failures.append(f"csse-budget: winner peak {b['peak_bytes']} "
                        f"exceeds budget {b['budget']}")
    store = by_name["memory/lm-stash/store"]
    quant1 = by_name["memory/lm-stash/quantized-mb1"]
    quant = by_name["memory/lm-stash/quantized"]
    rec = by_name["memory/lm-stash/recompute"]
    # Dtype invariant at EQUAL microbatch counts: fp8 stash payload is
    # half the bf16 store payload, accumulation playing no part.
    if (quant1["microbatches"] != store["microbatches"]
            or store["peak_bytes"] < 2 * quant1["peak_bytes"]):
        failures.append(
            f"lm-stash: quantized stash {quant1['peak_bytes']}B "
            f"(mb={quant1['microbatches']}) is not >=2x below store "
            f"{store['peak_bytes']}B (mb={store['microbatches']}) "
            f"(ISSUE acceptance)")
    # And the budgeted run must actually fit its budget.
    if quant["budget"] and quant["peak_bytes"] > quant["budget"]:
        failures.append(
            f"lm-stash: budgeted quantized stash {quant['peak_bytes']}B "
            f"exceeds the {quant['budget']}B budget")
    if rec["peak_bytes"] >= store["peak_bytes"]:
        failures.append(
            f"lm-stash: recompute stash {rec['peak_bytes']}B does not "
            f"undercut store {store['peak_bytes']}B")
    return failures


if __name__ == "__main__":
    rows = run()
    problems = validate(rows)
    for p in problems:
        print("FAIL:", p)
    raise SystemExit(1 if problems else 0)
