"""The paper's benchmark workloads (Table II) as tensorized layer specs.

Layer shapes follow the cited sources: ATIS/WMT transformers use the TT
format of [56] (Fig. 4's 768x768 example), BERT the TT of CoMERA [21], and
the UCF-11 LSTM the BT/HT/TR/TTM factorizations of [38]/[37]/[36]/[34]
(57600 -> 256 input-to-hidden projection, which is where the 4-to-5-digit
compression ratios in Table II come from).
"""

from __future__ import annotations

import dataclasses

from repro.core import factorizations as F


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    method: str
    fact: F.Factorization
    tokens: int              # batch dimension entering the layer


def paper_workloads() -> list[Workload]:
    return [
        # Transformer on ATIS — TT, d=768 attention/MLP projection.
        Workload("ATIS-TT", "tt",
                 F.tt((12, 8, 8), (8, 8, 12), 8), tokens=128),
        # Transformer on WMT14 — TT with the long-sequence batch the paper
        # calls out (intermediate blow-up => memory-access increase).
        Workload("WMT-TT", "tt",
                 F.tt((12, 8, 8), (8, 8, 12), 16), tokens=2048),
        # BERT on SQuAD — TT on the 768->3072 FFN.
        Workload("BERT-TT", "tt",
                 F.tt((16, 12, 16), (8, 8, 12), 16), tokens=512),
        # LSTM on UCF-11 — four decompositions of the 57600->256 projection.
        Workload("UCF-TTM", "ttm",
                 F.ttm((4, 4, 4, 4), (8, 10, 9, 10), 4), tokens=64),
        Workload("UCF-TR", "tr",
                 F.tr((4, 4, 4, 4), (8, 10, 9, 10), 4), tokens=64),
        Workload("UCF-HT", "ht",
                 F.ht((4, 4, 4, 4), (8, 10, 9, 10), 4), tokens=64),
        Workload("UCF-BT", "bt",
                 F.bt((4, 4, 4, 4), (8, 10, 9, 10), 4, num_blocks=2),
                 tokens=64),
    ]


def llm_scale_workloads() -> list[Workload]:
    """Beyond-paper: TNN at LLM scale, where rank >= 128 keeps the 128-wide
    MXU saturated — the regime where tensorized training wins on real TPUs
    (the paper's small-rank edge workloads are utilisation-starved there)."""
    return [
        # phi4-mini-class MLP: 3072 -> 8192, TT rank 128, a training batch.
        Workload("LLM-MLP-TT-r128", "tt",
                 F.tt((16, 16, 32), (16, 16, 12), 128), tokens=8192),
        # qwen2-class MLP: 3584 -> 18944, TTM rank 128.
        Workload("LLM-MLP-TTM-r128", "ttm",
                 F.ttm((37, 16, 32), (14, 16, 16), 128), tokens=8192),
    ]
