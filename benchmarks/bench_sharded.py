"""Sharded-execution benchmark: comm-aware vs comm-free CSSE on a fake
8-device mesh.

For each workload/phase the comm-free (single-device) stage-2 winner and
the communication-aware one are searched, both are priced under the
mesh-aware model, and the *real* sharded ``execute`` of the comm-aware
winner is timed on an 8-fake-host-device mesh
(``--xla_force_host_platform_device_count=8``) against the single-device
einsum reference for a parity check.  Claims validated on every run:

* the comm-aware objective flips the winning contraction sequence on at
  least one workload/phase (ISSUE acceptance; the flip table is documented
  in ``docs/SHARDING.md``);
* the comm-aware winner is never worse than the comm-free winner under the
  mesh model (reranking can only help on its own objective);
* sharded execution matches the single-device reference (parity within
  f32 tolerance);
* the WG stash policy flips shared→indep once the dW all-reduce is priced.

Forcing host devices requires setting ``XLA_FLAGS`` before jax initialises,
so the measurement runs in a subprocess and reports rows as JSON — the
same isolation the 8-device tests use.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

_WORKER = r"""
import json, time
import jax, jax.numpy as jnp
from repro.core import contraction, csse, factorizations as F
from repro.core import perf_model as pm
from repro.core import tensorized as tz
from repro.core.tnetwork import plan_from_tree
from repro.distributed import sharding

fact = F.tt((12, 8, 8), (8, 8, 12), 8)          # ATIS-TT (Table II)
tokens = 128
mesh = jax.make_mesh((8, 1), ("data", "model"))
mspec = sharding.mesh_spec(mesh, {"b": ("data",)})

rows = []
phases = {
    "fp": fact.forward_network(batch_axes=(("b", tokens),)),
    "bp": tz._bp_network(fact, tokens),
    "wg0": tz._wg_network(fact, tokens, 0),
}
for phase, net in phases.items():
    free = csse.search(net, csse.SearchOptions(objective="latency",
                                               fused_chain=True))
    aware = csse.search(net, csse.SearchOptions(objective="latency",
                                                fused_chain=True,
                                                mesh=mspec))
    free_on_mesh = pm.evaluate(free.plan, fused_chain=True, mesh=mspec)

    arrays = [jax.random.normal(jax.random.key(i), net.node_shape(i),
                                jnp.float32) / 8
              for i in range(net.num_nodes)]
    ref = contraction.execute(aware.plan, arrays)
    fn = jax.jit(lambda ts: contraction.execute(aware.plan, ts, mesh=mesh))
    got = fn(arrays)
    parity = float(jnp.max(jnp.abs(got - ref)) / jnp.max(jnp.abs(ref)))
    got.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        fn(arrays).block_until_ready()
    wall = (time.perf_counter() - t0) / 3

    rows.append({
        "name": f"sharded/ATIS-TT/{phase}",
        "wall_s": wall,
        "fusion_hit_rate": None,
        "flip": free.tree != aware.tree,
        "free_winner_mesh_latency_us": free_on_mesh.latency_s * 1e6,
        "aware_winner_mesh_latency_us": aware.cost.latency_s * 1e6,
        "collective_bytes": aware.cost.bytes_ici,
        "parity_rel_err": parity,
        "devices": jax.device_count(),
    })

# WG stash policy: the dW all-reduce flips shared -> indep on the mesh.
_, _, (kind_free, _, _) = tz._plans(
    fact, tokens, csse.SearchOptions(objective="latency", fused_chain=True))
_, _, (kind_aware, _, _) = tz._plans(
    fact, tokens, csse.SearchOptions(objective="latency", fused_chain=True,
                                     mesh=mspec))
dw_plan = csse.search(tz._dw_network(fact, tokens)).plan
rows.append({
    "name": "sharded/ATIS-TT/wg-policy",
    "wall_s": 0.0,
    "fusion_hit_rate": None,
    "policy_free": kind_free,
    "policy_aware": kind_aware,
    "dw_allreduce_bytes": pm.collective_cost(dw_plan, mspec,
                                             pm.TPU_V5E).bytes_ici,
    "devices": jax.device_count(),
})

# Pipeline bubble: 1F1B staged execution on this mesh, modeled (S-1)/(M+S-1)
# vs measured idle fraction (docs/DISTRIBUTED.md).  The report is the best
# warm step (per-stage jits compile on step 0); the drift also rides the
# telemetry drift channel, counted here so the record's presence is gated.
from repro import telemetry as tm
from repro.distributed import pipeline as pipe
tm.configure()
prep = pipe._demo_report(2, 4, 4)["report"]
ndrift = len([r for r in tm.drift_records()
              if r["name"] == "pipeline.bubble"])
rows.append({
    "name": "sharded/pipeline/bubble",
    "wall_s": prep["makespan_s"],
    "fusion_hit_rate": None,
    "num_stages": prep["num_stages"],
    "num_microbatches": prep["num_microbatches"],
    "modeled_bubble": prep["modeled_bubble"],
    "measured_bubble": prep["measured_bubble"],
    "bubble_drift": prep["drift"],
    "drift_records": ndrift,
    "devices": jax.device_count(),
})
print("ROWS=" + json.dumps(rows))
"""


def run(print_fn=print) -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _WORKER],
                         capture_output=True, text=True, timeout=900,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    line = next(ln for ln in out.stdout.splitlines()
                if ln.startswith("ROWS="))
    rows = json.loads(line[len("ROWS="):])
    for r in rows:
        if "flip" in r:
            print_fn(
                f"{r['name']}: flip={r['flip']} "
                f"free={r['free_winner_mesh_latency_us']:.2f}us "
                f"aware={r['aware_winner_mesh_latency_us']:.2f}us "
                f"ici={r['collective_bytes']}B "
                f"exec={r['wall_s']*1e3:.2f}ms "
                f"parity={r['parity_rel_err']:.1e}")
        elif "bubble_drift" in r:
            print_fn(
                f"{r['name']}: S={r['num_stages']} "
                f"M={r['num_microbatches']} "
                f"modeled={r['modeled_bubble']:.3f} "
                f"measured={r['measured_bubble']:.3f} "
                f"drift={r['bubble_drift']:.2f}x "
                f"({r['drift_records']} drift records)")
        else:
            print_fn(f"{r['name']}: {r['policy_free']} -> "
                     f"{r['policy_aware']} "
                     f"(dW all-reduce {r['dw_allreduce_bytes']}B)")
    return rows


def validate(rows) -> list[str]:
    failures: list[str] = []
    phase_rows = [r for r in rows if "flip" in r]
    if not any(r["flip"] for r in phase_rows):
        failures.append("comm-aware stage-2 flipped no winner on any phase")
    for r in phase_rows:
        if r["aware_winner_mesh_latency_us"] > \
                r["free_winner_mesh_latency_us"] * (1 + 1e-9):
            failures.append(
                f"{r['name']}: comm-aware winner worse than comm-free "
                "under the mesh model")
        if r["parity_rel_err"] > 1e-5:
            failures.append(f"{r['name']}: sharded parity "
                            f"{r['parity_rel_err']:.2e} > 1e-5")
        if r["devices"] != 8:
            failures.append(f"{r['name']}: ran on {r['devices']} devices, "
                            "expected 8")
    bubble = next((r for r in rows if "bubble_drift" in r), None)
    if bubble is None:
        failures.append("no pipeline bubble record")
    else:
        d = bubble["bubble_drift"]
        if max(d, 1.0 / max(d, 1e-9)) > 1.5:
            failures.append(
                f"pipeline bubble drift {d:.2f}x outside the 1.5x gate "
                f"(modeled {bubble['modeled_bubble']:.3f}, measured "
                f"{bubble['measured_bubble']:.3f})")
        if bubble["drift_records"] < 1:
            failures.append("pipeline step emitted no pipeline.bubble "
                            "telemetry drift record")
    policy = next(r for r in rows if r["name"].endswith("wg-policy"))
    if (policy["policy_free"], policy["policy_aware"]) != \
            ("shared", "indep"):
        failures.append(
            f"WG stash policy {policy['policy_free']} -> "
            f"{policy['policy_aware']}; expected shared -> indep once the "
            "dW all-reduce is priced")
    return failures


if __name__ == "__main__":
    rows = run()
    problems = validate(rows)
    for p in problems:
        print("FAIL:", p)
    raise SystemExit(1 if problems else 0)
