"""Training-specific sequences (the paper's §III-A/§IV core claim):
searching FP/BP/WG separately vs reusing the FP-optimal tree for all
phases.  Modeled FLOPs and latency per workload."""

from __future__ import annotations

from repro.core import csse
from repro.core.tensorized import _bp_network, _wg_network, _plans

from benchmarks.workloads import paper_workloads


def run(print_fn=print) -> list[dict]:
    rows = []
    opts = csse.SearchOptions(objective="edp")
    for wl in paper_workloads():
        fact, tokens = wl.fact, wl.tokens
        fp, bp, (wg_kind, dw, wg) = _plans(fact, tokens, opts)
        searched_lat = (fp.cost.latency_s + bp.cost.latency_s
                        + (dw.cost.latency_s if wg_kind == "shared" else 0)
                        + sum(w.cost.latency_s for w in wg))
        # Reuse baseline: run BP/WG networks under the *FP-found* tree
        # shape — approximated by their fixed (anchored-ascending) order,
        # which is what an autodiff transpose of the FP plan yields.
        bp_net = _bp_network(fact, tokens)
        reuse_bp = csse.fixed_plan(bp_net, fact.fixed_tree(bp_net))
        reuse_lat = fp.cost.latency_s + reuse_bp.cost.latency_s
        for i in range(fact.num_cores):
            wg_net = _wg_network(fact, tokens, i)
            reuse_lat += csse.fixed_plan(
                wg_net, fact.fixed_tree(wg_net)).cost.latency_s
        rows.append({
            "workload": wl.name,
            "searched_us": searched_lat * 1e6,
            "reuse_us": reuse_lat * 1e6,
            "speedup": reuse_lat / searched_lat,
        })
    print_fn(f"{'workload':10s} {'searched_us':>12s} {'reuse_us':>10s} "
             f"{'speedup':>8s}")
    for r in rows:
        print_fn(f"{r['workload']:10s} {r['searched_us']:12.1f} "
                 f"{r['reuse_us']:10.1f} {r['speedup']:8.2f}")
    return rows


def validate(rows) -> list[str]:
    bad = [r["workload"] for r in rows if r["speedup"] < 0.999]
    avg = sum(r["speedup"] for r in rows) / len(rows)
    failures = []
    if bad:
        failures.append(f"phase-search slower than reuse on {bad}")
    if avg < 1.05:
        failures.append(f"avg phase-search speedup only {avg:.3f}")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
