"""Joint plan search benchmark: the ISSUE-7 measurement-budget claim.

Two arms search the same rank-16 TT workload over the same combo space
(fusion x chain length x precision x stash) and tile grid, then both
winning plans are
re-priced by one fresh shared evaluation tuner so neither arm's own
measurement noise decides the comparison:

* **exhaustive** — the PR-1..6 pipeline: one ``objective="measured"``
  CSSE search per (fusion x precision) combo, full tile sweep, the
  measured stage-2 rerank over the default 8 candidate plans.  Every
  tuner trial is counted.
* **joint** — :func:`repro.core.search.joint_search` with the
  successive-halving sweep and the learned cost model (fit from the
  exhaustive arm's measurement DB — the "train on the autotune cache you
  already have" story of docs/SEARCH.md), measuring only the model's
  top-ranked finalist combo with a 2-plan rerank (cross-combo
  adjudication is the model's job — measured margins inside the tuner's
  noise floor defer to it anyway via ``search.MEASURED_TIE_BAND``).

Claims, checked on every run (CPU interpret mode in CI):

* joint spends **>= 5x fewer tuner trials** than exhaustive;
* at the shared evaluation, joint's plan is **equal-or-better** (a 1.25x
  band absorbs interpret-mode timer noise; the typical run re-discovers
  the identical plan, ratio 1.0);
* the analytic ATIS-TT weight-gradient row *converges*: the megakernel
  compiler's regrouping link predicate fuses the per-axis pipeline's
  frozen sequence too, so ISSUE-7's fusion-axis flip is closed — the
  joint loop must never lose to the per-axis baseline (both winners
  fused; today it strictly wins on a sequence flip) without spending a
  single measurement.
"""

from __future__ import annotations

import tempfile
import time

from repro.core import autotune, csse, factorizations as F, search
from repro.core import tensorized
from repro.core.policy import ExecutionPolicy
from repro.precision.policy import QuantPolicy

# Rank-16 TT over 512x512: contracted dims reach 128, so the 5-value tile
# grid is real (~100 configs/shape) instead of clamping to a handful —
# the regime the halving sweep exists for.
GRID = (8, 16, 32, 64, 128)
TOKENS = 64
MAX_CONFIGS = 100


def _fact():
    return F.tt((8, 8, 8), (8, 8, 8), 16)


def run(print_fn=print, cache_dir: str | None = None) -> list[dict]:
    cache_dir = cache_dir or tempfile.mkdtemp(prefix="repro-search-bench-")
    net = _fact().forward_network(batch_axes=(("b", TOKENS),))
    space = search.SearchSpace()

    # -- exhaustive arm: measured CSSE per combo, full sweep ---------------
    d_ex = tempfile.mkdtemp(dir=cache_dir)
    ex_tuner = autotune.Tuner(cache_dir=d_ex, tile_sweep=GRID, iters=1,
                              max_configs=MAX_CONFIGS)
    csse.clear_memo()
    t0 = time.perf_counter()
    ex_lat, ex_combo, ex_plan, ex_xp = float("inf"), None, None, None
    for fused in space.fused:
        # The chain-length axis only exists under fusion (same combo
        # enumeration as SearchSpace.combos).
        for ln in (space.chain_lens if fused else space.chain_lens[:1]):
            for prec in space.precisions:
                xp = ExecutionPolicy(objective="measured",
                                     fused_chain=fused, max_chain_len=ln,
                                     precision=QuantPolicy.parse(prec),
                                     tile_sweep=GRID)
                res = csse.search(net, xp, tuner=ex_tuner)
                lat = ex_tuner.plan_latency_policy(res.plan, xp)
                if lat < ex_lat:
                    ex_lat, ex_combo = lat, (fused, ln, prec)
                    ex_plan, ex_xp = res.plan, xp
    ex_wall = time.perf_counter() - t0
    ex_trials = ex_tuner.stats["trials"]
    print_fn(f"[search] exhaustive: {ex_trials} trials {ex_wall:.1f}s "
             f"combo={ex_combo}")

    # The learned model trains on the measurement DB the exhaustive arm
    # just wrote, and persists next to it.
    model = search.CostModel.fit_from_cache(d_ex)

    # -- joint arm: halving sweep + model-ranked finalists -----------------
    d_j = tempfile.mkdtemp(dir=cache_dir)
    j_xp = ExecutionPolicy(objective="measured", tile_sweep=GRID,
                           sweep_strategy="halving")
    # iters=3: finalists are adjudicated against each other on ~1% margins;
    # extra timing iterations harden that comparison at zero cost to the
    # trials claim (stats["trials"] counts configs, and the halving sweep
    # only spends full iters on its last rungs).
    j_tuner = autotune.Tuner.from_policy(j_xp, cache_dir=d_j, iters=3,
                                         max_configs=MAX_CONFIGS)
    csse.clear_memo()
    t0 = time.perf_counter()
    jr = search.joint_search(net, j_xp, tuner=j_tuner, model=model,
                             space=space, measure_top=1,
                             finalist_candidates=2)
    j_wall = time.perf_counter() - t0
    w = jr.best
    j_combo = (w.policy.fused_chain, w.policy.max_chain_len,
               w.policy.policy_tag or "bf16")
    print_fn(f"[search] joint: {jr.measurements} trials {j_wall:.1f}s "
             f"combo={j_combo}")

    # -- shared evaluation: one fresh tuner prices both winners ------------
    d_ev = tempfile.mkdtemp(dir=cache_dir)
    ev = autotune.Tuner(cache_dir=d_ev, tile_sweep=GRID, iters=3,
                        max_configs=MAX_CONFIGS)
    eval_ex = ev.plan_latency_policy(ex_plan, ex_xp)
    eval_j = ev.plan_latency_policy(w.result.plan, w.policy)
    trials_ratio = ex_trials / max(1, jr.measurements)
    lat_ratio = eval_j / eval_ex
    print_fn(f"[search] eval: exhaustive {eval_ex:.3e}s joint {eval_j:.3e}s "
             f"-> {trials_ratio:.1f}x fewer trials, lat ratio "
             f"{lat_ratio:.2f}")

    # -- analytic flip row: zero measurements ------------------------------
    t0 = time.perf_counter()
    wg = tensorized._wg_network(F.tt((12, 8, 8), (8, 8, 12), 8), 128, 0)
    flip = search.joint_search(wg, ExecutionPolicy(objective="latency"))
    flip_wall = time.perf_counter() - t0

    return [
        {"name": "search/exhaustive", "wall_s": ex_wall,
         "fusion_hit_rate": None, "measurements": ex_trials,
         "eval_latency_s": eval_ex, "combo": f"{ex_combo}"},
        {"name": "search/joint", "wall_s": j_wall,
         "fusion_hit_rate": None, "measurements": jr.measurements,
         "eval_latency_s": eval_j, "combo": f"{j_combo}",
         "trials_ratio": trials_ratio, "lat_ratio": lat_ratio,
         "model_used": float(jr.model_used)},
        {"name": "search/flip_atis_wg", "wall_s": flip_wall,
         "fusion_hit_rate": None, "measurements": flip.measurements,
         "converged": float(
             flip.best.modeled_s <= flip.per_axis.modeled_s + 1e-15
             and flip.best.policy.fused_chain
             and flip.per_axis.policy.fused_chain),
         "joint_modeled_s": flip.best.modeled_s,
         "per_axis_modeled_s": flip.per_axis.modeled_s},
    ]


def validate(rows: list[dict]) -> list[str]:
    by = {r["name"]: r for r in rows}
    joint, flip = by["search/joint"], by["search/flip_atis_wg"]
    failures = []
    if joint["trials_ratio"] < 5.0:
        failures.append(
            f"joint search spent only {joint['trials_ratio']:.2f}x fewer "
            f"measurements than exhaustive (claim: >= 5x)")
    if joint["lat_ratio"] > 1.25:
        failures.append(
            f"joint plan {joint['lat_ratio']:.2f}x slower than exhaustive "
            f"at the shared evaluation (claim: equal-or-better, 1.25x "
            f"noise band)")
    if not joint["model_used"]:
        failures.append("cost model did not fit from the exhaustive DB")
    if not flip["converged"]:
        failures.append(
            "ATIS-TT WG joint search failed to converge on the per-axis "
            "optimum (megakernel compiler closed ISSUE-7's flip; joint "
            "must never lose to per-axis, both winners fused)")
    if flip["measurements"] != 0:
        failures.append("analytic flip row spent measurements")
    return failures


if __name__ == "__main__":
    fails = validate(run())
    for f in fails:
        print("FAIL:", f)
    raise SystemExit(1 if fails else 0)
