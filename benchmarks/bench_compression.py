"""Table II reproduction: parameter compression ratios (exact, by
construction) for the paper's benchmark factorizations."""

from __future__ import annotations

from benchmarks.workloads import paper_workloads


def run(print_fn=print) -> list[dict]:
    rows = []
    for wl in paper_workloads():
        f = wl.fact
        rows.append({
            "workload": wl.name, "method": wl.method.upper(),
            "dense_params": f.dense_params, "tnn_params": f.num_params,
            "ratio": f.compression_ratio,
        })
    print_fn(f"{'workload':10s} {'method':7s} {'dense':>12s} {'tnn':>8s} "
             f"{'ratio':>10s}")
    for r in rows:
        print_fn(f"{r['workload']:10s} {r['method']:7s} "
                 f"{r['dense_params']:12,d} {r['tnn_params']:8,d} "
                 f"{r['ratio']:10.1f}")
    return rows


def validate(rows) -> list[str]:
    failures = []
    by = {r["workload"]: r for r in rows}
    # UCF LSTM rows must land in Table II's 4-5 digit compression regime.
    for wl in ("UCF-TTM", "UCF-TR", "UCF-HT", "UCF-BT"):
        if by[wl]["ratio"] < 1000:
            failures.append(f"{wl}: ratio {by[wl]['ratio']:.0f} < 1000")
    if not 3 < by["ATIS-TT"]["ratio"] < 10000:
        failures.append("ATIS-TT ratio out of plausible range")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
