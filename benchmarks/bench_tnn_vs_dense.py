"""Fig. 14 reproduction (modeled): tensorized vs dense training cost.

Per workload: full training-step cost (FP + BP + WG) of the tensorized
layer under CSSE sequences vs the dense layer, on the TPU perf model —
speedup and energy-reduction ratios analogous to Fig. 14's FETTA-vs-dense
bars (absolute values differ: TPU v5e chip model, not the 256-MAC ASIC).
"""

from __future__ import annotations

from repro.core import csse, perf_model
from repro.core.tensorized import layer_cost
from repro.core.tnetwork import TensorNetwork, plan_from_tree

from benchmarks.workloads import llm_scale_workloads, paper_workloads


def dense_train_cost(fact, tokens, hw=perf_model.TPU_V5E):
    """FP + BP + WG of the dense layer (three GEMMs, Eq. 6)."""
    total_lat, total_e = 0.0, 0.0
    for (a, b, out) in [
        (("b", "n"), ("m", "n"), ("b", "m")),     # FP:  X W^T
        (("b", "m"), ("m", "n"), ("b", "n")),     # BP:  dY W
        (("b", "m"), ("b", "n"), ("m", "n")),     # WG:  dY^T X
    ]:
        net = TensorNetwork(
            sizes={"b": tokens, "n": fact.N, "m": fact.M},
            nodes=(a, b), node_names=("A", "B"), output=out)
        c = perf_model.evaluate(plan_from_tree(net, (0, 1)), hw)
        total_lat += c.latency_s
        total_e += c.energy_j
    return total_lat, total_e


def run(print_fn=print) -> list[dict]:
    """Two hardware regimes:
    * ``fetta-256mac`` — the paper's methodology (all baselines scaled to
      256 MACs, §VI-B): reproduces Fig. 14's TNN-beats-dense result.
    * ``tpu-v5e`` — the real target chip: the paper's small-rank edge
      workloads lose to dense (a 128-wide MXU runs rank-4..16 contractions
      at <12% utilisation — Fig. 6's observation, quantified), while the
      LLM-scale rank-128 workloads win.  This rank>=128 crossover is the
      central hardware-adaptation finding (DESIGN.md §2).
    """
    rows = []
    opts = csse.SearchOptions(objective="edp")
    for hw_name, hw, wls in [
        ("fetta-256mac", perf_model.FETTA_EDGE, paper_workloads()),
        ("tpu-v5e", perf_model.TPU_V5E,
         paper_workloads() + llm_scale_workloads()),
    ]:
        for wl in wls:
            costs = layer_cost(wl.fact, wl.tokens, opts, hw=hw)
            tnn_lat = sum(c.latency_s for c in costs.values())
            tnn_e = sum(c.energy_j for c in costs.values())
            d_lat, d_e = dense_train_cost(wl.fact, wl.tokens, hw)
            rows.append({
                "hw": hw_name, "workload": wl.name,
                "tnn_lat_us": tnn_lat * 1e6, "dense_lat_us": d_lat * 1e6,
                "speedup": d_lat / tnn_lat,
                "energy_red": d_e / tnn_e,
                "compression": wl.fact.compression_ratio,
            })
    print_fn(f"{'hw':13s} {'workload':17s} {'tnn_us':>9s} {'dense_us':>9s} "
             f"{'speedup':>8s} {'E_red':>7s} {'compress':>9s}")
    for r in rows:
        print_fn(f"{r['hw']:13s} {r['workload']:17s} {r['tnn_lat_us']:9.1f} "
                 f"{r['dense_lat_us']:9.1f} {r['speedup']:8.2f} "
                 f"{r['energy_red']:7.2f} {r['compression']:9.0f}")
    return rows


# Fig. 14's gated task set: one decomposition per task (UCF is represented
# by TTM/TR there).  HT/BT rows are reported but not gated: their WG phase
# runs d+1 gradient networks against a 64-token batch -- a structural
# overhead the paper amortises with cross-network intermediate reuse that we
# implement only as the shared-dW policy (full WG-CSE is future work, see
# DESIGN.md).  On v5e the gate is the rank-128 TT crossover result.
_EDGE_GATED = {"ATIS-TT", "WMT-TT", "BERT-TT", "UCF-TTM", "UCF-TR"}


def validate(rows) -> list[str]:
    failures = []
    for r in rows:
        if (r["hw"] == "fetta-256mac" and r["workload"] in _EDGE_GATED
                and r["speedup"] < 1.0):
            failures.append(f"{r['workload']}: no speedup on edge model "
                            f"({r['speedup']:.2f})")
        if (r["hw"] == "tpu-v5e" and r["workload"] == "LLM-MLP-TT-r128"
                and r["speedup"] < 1.0):
            failures.append(f"{r['workload']}: rank-128 TT should beat "
                            f"dense on v5e ({r['speedup']:.2f})")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
