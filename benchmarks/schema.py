"""The machine-readable benchmark record schema shared by all bench_*
scripts.

Every benchmark row normalises to one flat record:

    {"name": str,              # "<module>/<case>" unique within a run
     "wall_s": float,          # wall seconds (modeled or measured)
     "fusion_hit_rate": float | None,   # None where fusion is meaningless
     "dtype": str | None,      # operand/storage dtype the case ran under
                               # (None = module is dtype-agnostic)
     "policy": str | None,     # quantization policy tag ("fp8_e4m3/tensor",
                               # ...; None = unquantized execution)
     "peak_bytes": int | None, # peak memory of the case (probe: measured
                               # on stats-capable devices, deterministic
                               # live-bytes model on CPU; None = module
                               # does not probe memory)
     "p50_ms": float | None,   # serving: request-latency percentiles,
     "p99_ms": float | None,   #   time-to-first-token, throughput and the
     "ttft_ms": float | None,  #   request count they were computed over
     "tok_per_s": float | None,  # (bench_serving only; p99_ms is gated
     "requests": int | None,   #   like wall_s, with its own noise floor)
     "measurements": int | None,  # tuner trials the case spent (plan-
                               # search modules: the budget currency of
                               # docs/SEARCH.md; None = module does not
                               # count measurements)
     "achieved_gbps": float | None,  # effective HBM bandwidth the case
                               # sustained (modeled lowering bytes /
                               # wall_s — the roofline report's achieved
                               # axis; None = module does not report it)
     "chain_len": int | None,  # longest megakernel chain the compiled
                               # plan emitted (0 = unfused; None = module
                               # does not compile plans)
     "device": str,            # jax backend:device_kind
     "git_sha": str,           # HEAD at run time ("unknown" outside git)
     "metrics": dict}          # benchmark-specific extras (floats/strs)

``benchmarks/run.py`` writes one ``BENCH_<module>.json`` per module
(``{"schema": 1, "records": [...]}``) and CI's bench-smoke job uploads them
as artifacts and gates ``wall_s`` *and* ``peak_bytes`` regressions against
the checked-in baseline (:func:`regression_failures`), rendering the
per-benchmark delta table into ``$GITHUB_STEP_SUMMARY``
(:func:`delta_table`).
"""

from __future__ import annotations

import json
import os
import subprocess

SCHEMA_VERSION = 1


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(__file__))
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def device() -> str:
    import jax
    return f"{jax.default_backend()}:{jax.devices()[0].device_kind}"


def make_record(name: str, wall_s: float,
                fusion_hit_rate: float | None = None,
                dtype: str | None = None, policy: str | None = None,
                peak_bytes: int | None = None,
                p50_ms: float | None = None, p99_ms: float | None = None,
                ttft_ms: float | None = None,
                tok_per_s: float | None = None,
                requests: int | None = None,
                measurements: int | None = None,
                achieved_gbps: float | None = None,
                chain_len: int | None = None,
                **metrics) -> dict:
    return {
        "name": name,
        "wall_s": float(wall_s),
        "fusion_hit_rate": (None if fusion_hit_rate is None
                            else float(fusion_hit_rate)),
        "dtype": dtype,
        "policy": policy,
        "peak_bytes": None if peak_bytes is None else int(peak_bytes),
        # serving fields (bench_serving; None for every non-serving module):
        # request-latency percentiles, time-to-first-token, throughput, and
        # the completed-request count the percentiles were computed over.
        "p50_ms": None if p50_ms is None else float(p50_ms),
        "p99_ms": None if p99_ms is None else float(p99_ms),
        "ttft_ms": None if ttft_ms is None else float(ttft_ms),
        "tok_per_s": None if tok_per_s is None else float(tok_per_s),
        "requests": None if requests is None else int(requests),
        # plan-search modules: tuner trials spent producing this record
        "measurements": None if measurements is None else int(measurements),
        # megakernel roofline: sustained HBM bandwidth + deepest chain
        "achieved_gbps": (None if achieved_gbps is None
                          else float(achieved_gbps)),
        "chain_len": None if chain_len is None else int(chain_len),
        "device": device(),
        "git_sha": git_sha(),
        "metrics": metrics,
    }


def write_json(path: str, records: list[dict]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA_VERSION, "records": records}, f,
                  indent=2, sort_keys=True)


def load_json(path: str) -> list[dict]:
    with open(path) as f:
        payload = json.load(f)
    assert payload.get("schema") == SCHEMA_VERSION, (
        f"{path}: schema {payload.get('schema')} != {SCHEMA_VERSION}")
    return payload["records"]


def regression_failures(records: list[dict], baseline: list[dict],
                        gate: float = 1.5,
                        min_wall_s: float = 0.05,
                        min_p99_ms: float = 5.0) -> list[str]:
    """Names whose wall_s, peak_bytes, or p99_ms regressed > ``gate``x.

    wall_s: records whose baseline wall_s is under ``min_wall_s`` are not
    gated — sub-50ms timings are dominated by dispatch/timer noise and
    would make the gate flap; they are still emitted and uploaded for
    trend tracking.

    peak_bytes: gated whenever both sides carry a value — memory probes
    are deterministic on CI's CPU leg (modeled live-bytes accounting), so
    there is no noise floor to carve out; a peak regression is a real
    planner/stash change, exactly what must not ship silently.

    p99_ms: the serving tail-latency gate — same noise-floor treatment as
    wall_s (``min_p99_ms``), since a sub-5ms p99 on the smoke model is
    timer jitter, not a scheduler property.

    fusion_hit_rate: gated on any *exact* drop — the compiler's fusion
    decisions are deterministic, so a lower hit rate means the planner
    stopped fusing something it used to fuse (a silent megakernel
    regression), never noise.

    achieved_gbps: the inverted bandwidth gate — fails when the sustained
    HBM bandwidth falls below ``1/gate`` of the baseline.  Derived from
    the same wall clock as ``wall_s``, so it shares that gate's
    ``min_wall_s`` noise floor.

    New records (absent from the baseline) never fail; deleting a
    baselined record does.
    """
    by_name = {r["name"]: r for r in records}
    failures = []
    for base in baseline:
        name = base["name"]
        got = by_name.get(name)
        if got is None:
            failures.append(f"{name}: present in baseline but not emitted")
            continue
        base_peak = base.get("peak_bytes")
        got_peak = got.get("peak_bytes")
        if base_peak is not None:
            if got_peak is None:
                # A record that stops probing memory is a loss of gate
                # coverage, not a pass — same policy as a vanished record.
                failures.append(
                    f"{name}: baseline has peak_bytes {base_peak} but the "
                    f"record no longer emits it")
            elif got_peak > gate * base_peak:
                failures.append(
                    f"{name}: peak_bytes {got_peak} > {gate}x baseline "
                    f"{base_peak}")
        base_p99 = base.get("p99_ms")
        got_p99 = got.get("p99_ms")
        if base_p99 is not None and base_p99 >= min_p99_ms:
            if got_p99 is None:
                failures.append(
                    f"{name}: baseline has p99_ms {base_p99} but the "
                    f"record no longer emits it")
            elif got_p99 > gate * base_p99:
                failures.append(
                    f"{name}: p99_ms {got_p99:.1f} > {gate}x baseline "
                    f"{base_p99:.1f}")
        base_hit = base.get("fusion_hit_rate")
        got_hit = got.get("fusion_hit_rate")
        if base_hit is not None:
            if got_hit is None:
                failures.append(
                    f"{name}: baseline has fusion_hit_rate {base_hit} but "
                    f"the record no longer emits it")
            elif got_hit < base_hit:
                failures.append(
                    f"{name}: fusion_hit_rate {got_hit:.3f} dropped below "
                    f"baseline {base_hit:.3f}")
        noisy_wall = base["wall_s"] < min_wall_s
        base_bw = base.get("achieved_gbps")
        got_bw = got.get("achieved_gbps")
        if base_bw is not None and not noisy_wall:
            if got_bw is None:
                failures.append(
                    f"{name}: baseline has achieved_gbps {base_bw:.3f} but "
                    f"the record no longer emits it")
            elif got_bw < base_bw / gate:
                failures.append(
                    f"{name}: achieved_gbps {got_bw:.3f} < baseline "
                    f"{base_bw:.3f} / {gate}")
        if noisy_wall:
            continue
        if got["wall_s"] > gate * base["wall_s"]:
            failures.append(
                f"{name}: wall_s {got['wall_s']:.4f} > {gate}x baseline "
                f"{base['wall_s']:.4f}")
    return failures


def delta_table(records: list[dict], baseline: list[dict]) -> str:
    """Markdown wall_s / peak_bytes delta table vs the baseline — what CI
    appends to ``$GITHUB_STEP_SUMMARY`` so a red gate is diagnosable
    without downloading artifacts."""

    def fmt_delta(got, base):
        if base is None:
            return "new" if got is not None else "-"
        if got is None:
            return "missing"
        if base == 0:
            return "-" if got == 0 else "from 0"
        return f"{(got / base - 1) * 100:+.1f}%"

    def fmt(v, spec=""):
        return "-" if v is None else format(v, spec)

    by_name = {r["name"]: r for r in baseline}
    lines = [
        "| benchmark | wall_s | baseline | Δ | peak_bytes | baseline | Δ "
        "| p99_ms | Δ | tok/s | Δ | meas | Δ |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        base = by_name.get(r["name"], {})
        bw = base.get("wall_s")
        bp = base.get("peak_bytes")
        gp = r.get("peak_bytes")
        b99, g99 = base.get("p99_ms"), r.get("p99_ms")
        bts, gts = base.get("tok_per_s"), r.get("tok_per_s")
        bm, gm = base.get("measurements"), r.get("measurements")
        lines.append(
            f"| {r['name']} "
            f"| {r['wall_s']:.4f} "
            f"| {fmt(bw, '.4f')} "
            f"| {fmt_delta(r['wall_s'], bw)} "
            f"| {fmt(gp)} "
            f"| {fmt(bp)} "
            f"| {fmt_delta(gp, bp)} "
            f"| {fmt(g99, '.1f')} "
            f"| {fmt_delta(g99, b99)} "
            f"| {fmt(gts, '.1f')} "
            f"| {fmt_delta(gts, bts)} "
            f"| {fmt(gm)} "
            f"| {fmt_delta(gm, bm)} |")
    emitted = {r["name"] for r in records}
    for base in baseline:
        if base["name"] not in emitted:
            bp = base.get("peak_bytes")
            lines.append(f"| {base['name']} | missing | "
                         f"{base['wall_s']:.4f} | missing | - | "
                         f"{fmt(bp)} | missing | - | - | - | - | - | - |")
    return "\n".join(lines)
