"""The machine-readable benchmark record schema shared by all bench_*
scripts.

Every benchmark row normalises to one flat record:

    {"name": str,              # "<module>/<case>" unique within a run
     "wall_s": float,          # wall seconds (modeled or measured)
     "fusion_hit_rate": float | None,   # None where fusion is meaningless
     "dtype": str | None,      # operand/storage dtype the case ran under
                               # (None = module is dtype-agnostic)
     "policy": str | None,     # quantization policy tag ("fp8_e4m3/tensor",
                               # ...; None = unquantized execution)
     "device": str,            # jax backend:device_kind
     "git_sha": str,           # HEAD at run time ("unknown" outside git)
     "metrics": dict}          # benchmark-specific extras (floats/strs)

``benchmarks/run.py`` writes one ``BENCH_<module>.json`` per module
(``{"schema": 1, "records": [...]}``) and CI's bench-smoke job uploads them
as artifacts and gates ``wall_s`` regressions against the checked-in
baseline (:func:`regression_failures`).
"""

from __future__ import annotations

import json
import os
import subprocess

SCHEMA_VERSION = 1


def git_sha() -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(__file__))
        return out.stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def device() -> str:
    import jax
    return f"{jax.default_backend()}:{jax.devices()[0].device_kind}"


def make_record(name: str, wall_s: float,
                fusion_hit_rate: float | None = None,
                dtype: str | None = None, policy: str | None = None,
                **metrics) -> dict:
    return {
        "name": name,
        "wall_s": float(wall_s),
        "fusion_hit_rate": (None if fusion_hit_rate is None
                            else float(fusion_hit_rate)),
        "dtype": dtype,
        "policy": policy,
        "device": device(),
        "git_sha": git_sha(),
        "metrics": metrics,
    }


def write_json(path: str, records: list[dict]) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA_VERSION, "records": records}, f,
                  indent=2, sort_keys=True)


def load_json(path: str) -> list[dict]:
    with open(path) as f:
        payload = json.load(f)
    assert payload.get("schema") == SCHEMA_VERSION, (
        f"{path}: schema {payload.get('schema')} != {SCHEMA_VERSION}")
    return payload["records"]


def regression_failures(records: list[dict], baseline: list[dict],
                        gate: float = 1.5,
                        min_wall_s: float = 0.05) -> list[str]:
    """Names whose wall_s regressed more than ``gate``x vs the baseline.

    Records whose baseline wall_s is under ``min_wall_s`` are not gated —
    sub-50ms timings are dominated by dispatch/timer noise and would make
    the gate flap; they are still emitted and uploaded for trend tracking.
    New records (absent from the baseline) never fail; deleting a
    baselined record does.
    """
    by_name = {r["name"]: r for r in records}
    failures = []
    for base in baseline:
        name = base["name"]
        got = by_name.get(name)
        if got is None:
            failures.append(f"{name}: present in baseline but not emitted")
            continue
        if base["wall_s"] < min_wall_s:
            continue
        if got["wall_s"] > gate * base["wall_s"]:
            failures.append(
                f"{name}: wall_s {got['wall_s']:.4f} > {gate}x baseline "
                f"{base['wall_s']:.4f}")
    return failures
