"""§V-B analogue: dataflow flexibility effect on the memory term.

FETTA's CE array keeps operands/psums stationary and the butterfly network
reorders layouts in flight; our TPU mapping realises the same effect with
VMEM-resident chaining (Pallas fused chain — `fused_chain` in the perf
model).  This benchmark quantifies that choice per workload: HBM bytes and
modeled latency with and without chaining, plus the kernel's VMEM working
set vs block shape (the BlockSpec trade-off)."""

from __future__ import annotations

from repro.core import csse, perf_model

from benchmarks.workloads import paper_workloads


def run(print_fn=print) -> list[dict]:
    rows = []
    for wl in paper_workloads():
        net = wl.fact.forward_network(batch_axes=(("b", wl.tokens),))
        res = csse.search(net, csse.SearchOptions(objective="edp"))
        base = perf_model.evaluate(res.plan, fused_chain=False)
        fused = perf_model.evaluate(res.plan, fused_chain=True)
        rows.append({
            "workload": wl.name,
            "bytes_base": base.bytes_hbm, "bytes_fused": fused.bytes_hbm,
            "bytes_red": base.bytes_hbm / max(fused.bytes_hbm, 1),
            "lat_red": base.latency_s / fused.latency_s,
        })
    print_fn(f"{'workload':10s} {'HBM_base':>10s} {'HBM_fused':>10s} "
             f"{'bytes_red':>10s} {'lat_red':>8s}")
    for r in rows:
        print_fn(f"{r['workload']:10s} {r['bytes_base']:10.2e} "
                 f"{r['bytes_fused']:10.2e} {r['bytes_red']:10.2f} "
                 f"{r['lat_red']:8.2f}")
    return rows


def validate(rows) -> list[str]:
    failures = []
    for r in rows:
        if r["bytes_red"] < 1.0:
            failures.append(f"{r['workload']}: chaining increased bytes")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
