"""Serving benchmark: continuous-batching latency/throughput under a
seeded Poisson arrival trace.

Drives ``repro.serving.ServeEngine`` tick-by-tick against a
deterministic open-loop trace (seeded exponential interarrival gaps
mapped onto engine ticks), measuring what a serving SLO actually
prices:

* ``p50_ms`` / ``p99_ms`` — request latency (submit -> last token);
  p99 is the tail the CI gate watches (``schema.regression_failures``
  gates it at the same 1.5x as wall_s, with a 5ms noise floor);
* ``ttft_ms``  — mean time-to-first-token (the chunked-prefill knob's
  target metric);
* ``tok_per_s`` — decode throughput over the whole run.

Two cases share one trace: the bf16 KV cache and the fp8-quantized KV
cache (same requests, same arrival ticks), so the delta between them
isolates the quantized cache's cost.  Compilation happens in
``engine.warmup()`` before the clock starts.

Claim checks (:func:`validate`): every submitted request completes,
outputs respect ``max_new_tokens``, and the fp8 case's modeled
per-slot payload is >= 2x below bf16's.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.models.lm import LM, LMConfig
from repro.serving.engine import Request, ServeEngine

SEED = 0
SMOKE = dict(requests=8, batch=4, prompt_len=12, max_new=8,
             prefill_chunk=8, arrival_rate=2.0)   # requests per tick
FULL = dict(requests=32, batch=8, prompt_len=48, max_new=32,
            prefill_chunk=16, arrival_rate=1.0)


def _smoke_model():
    cfg = LMConfig(name="serve-smoke", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                   vocab=256, remat=False)
    model = LM(cfg)
    params = model.init(jax.random.key(SEED))
    return model, params, cfg


def _poisson_trace(n: int, rate: float, prompt_len: int, max_new: int,
                   vocab: int) -> list[tuple[int, Request]]:
    """(arrival_tick, request) pairs from seeded exponential gaps."""
    rng = np.random.default_rng(SEED)
    t = 0.0
    out = []
    for rid in range(n):
        t += rng.exponential(1.0 / rate)
        out.append((int(t), Request(
            rid=rid,
            prompt=rng.integers(0, vocab, size=prompt_len, dtype=np.int32),
            max_new_tokens=max_new)))
    return out


def _drive(engine: ServeEngine, trace) -> dict:
    """Run the trace to completion; wall-clock percentiles per request."""
    engine.warmup()
    pending = list(trace)
    t0 = time.perf_counter()
    while pending or engine.busy:
        while pending and pending[0][0] <= engine.tick:
            engine.submit(pending.pop(0)[1])
        engine.step()
    wall = time.perf_counter() - t0
    done = engine.completed
    lat_ms = np.array([(r.t_done - r.t_submit) * 1e3 for r in done])
    ttft_ms = np.array([r.ttft_s * 1e3 for r in done])
    tokens = sum(len(r.out_tokens) for r in done)
    return {
        "wall_s": wall,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "ttft_ms": float(np.mean(ttft_ms)),
        "tok_per_s": tokens / wall,
        "requests": len(done),
        "tokens": tokens,
        "ticks": engine.tick,
        "max_occupancy": engine.max_occupancy,
    }


def run(print_fn=print, smoke: bool = True) -> list[dict]:
    p = SMOKE if smoke else FULL
    model, params, cfg = _smoke_model()
    max_len = p["prompt_len"] + p["max_new"]
    rows = []
    for case, kv in (("bf16_kv", None), ("fp8_kv", "fp8")):
        trace = _poisson_trace(p["requests"], p["arrival_rate"],
                               p["prompt_len"], p["max_new"], cfg.vocab)
        engine = ServeEngine(
            model, params, batch_size=p["batch"], max_len=max_len,
            prefill_chunk=p["prefill_chunk"], kv_policy=kv)
        stats = _drive(engine, trace)
        slot = engine.slot_cost
        rows.append({
            "name": f"serving/poisson/{case}",
            "wall_s": stats["wall_s"],
            "fusion_hit_rate": None,
            "dtype": "fp8_e4m3" if kv else "bf16",
            "policy": f"{engine.kv_policy.tag}" if kv else None,
            "peak_bytes": slot["total"] * engine.capacity,
            "p50_ms": stats["p50_ms"],
            "p99_ms": stats["p99_ms"],
            "ttft_ms": stats["ttft_ms"],
            "tok_per_s": stats["tok_per_s"],
            "requests": stats["requests"],
            "slot_payload_bytes": slot["payload"],
            "slot_meta_bytes": slot["meta"],
            "tokens": stats["tokens"],
            "ticks": stats["ticks"],
            "max_occupancy": stats["max_occupancy"],
            "submitted": p["requests"],
            "max_new": p["max_new"],
        })
        print_fn(
            f"{rows[-1]['name']:30s} p50={stats['p50_ms']:.1f}ms "
            f"p99={stats['p99_ms']:.1f}ms ttft={stats['ttft_ms']:.1f}ms "
            f"{stats['tok_per_s']:.1f} tok/s "
            f"slot={slot['total']}B occ<={stats['max_occupancy']}")
    return rows


def validate(rows) -> list[str]:
    failures = []
    by_case = {r["name"].rsplit("/", 1)[-1]: r for r in rows}
    for r in rows:
        if r["requests"] != r["submitted"]:
            failures.append(
                f"{r['name']}: {r['requests']}/{r['submitted']} requests "
                f"completed")
        if r["tokens"] > r["requests"] * r["max_new"]:
            failures.append(
                f"{r['name']}: emitted {r['tokens']} tokens > "
                f"requests * max_new")
    bf16 = by_case.get("bf16_kv")
    fp8 = by_case.get("fp8_kv")
    if bf16 and fp8:
        cut = bf16["slot_payload_bytes"] / fp8["slot_payload_bytes"]
        if cut < 2.0:
            failures.append(
                f"fp8 KV payload cut {cut:.2f}x < 2x "
                f"({bf16['slot_payload_bytes']} -> "
                f"{fp8['slot_payload_bytes']} bytes/slot)")
    return failures


if __name__ == "__main__":
    import argparse

    from repro import telemetry as tm

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--serve-trace", default=None, metavar="PATH",
                    help="write a telemetry trace of the benchmark run "
                         "(per-request lifecycle lanes, tick spans, "
                         "occupancy samples; '*.jsonl' streams, other "
                         "suffixes write Chrome trace-event JSON)")
    args = ap.parse_args()
    owns_trace = bool(args.serve_trace) and not tm.enabled()
    if owns_trace:
        tm.configure(args.serve_trace)
    for row in run(smoke=True):
        print(row)
    errs = validate(run(print_fn=lambda *_: None, smoke=True))
    if owns_trace:
        tm.finalize()
    raise SystemExit(1 if errs else 0)
