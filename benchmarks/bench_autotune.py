"""Autotuner benchmark: the measure→model loop on a paper workload.

Three claims, checked on every run (CPU interpret mode in CI):

* **cold tune** — a fresh cache tunes every lowered step shape of the
  ATIS-TT FP plan (measured > 0);
* **warm tune** — a second tuner over the same cache re-measures nothing
  (the content-addressed disk cache is a 100% hit), and the warm search is
  orders of magnitude faster than the cold one;
* **reranking bites** — ``objective="measured"`` perturbs the analytic
  ranking somewhere: a different stage-2 winner, a non-default tile
  config, or any difference in the full stage-2 candidate order.  (The
  megakernel-era perf model mirrors the compiler's fusion predicate, so
  the analytic and measured *winners* now frequently agree — the wider
  evidence set keeps the claim about the mechanism, not about model
  error.)  A run where all three agree is re-sampled once with a fresh
  tuner before it counts as a failure.
"""

from __future__ import annotations

import tempfile
import time

from repro.core import autotune, csse, perf_model
from repro.core.tnetwork import plan_from_tree

from benchmarks.workloads import paper_workloads


def _atis():
    return next(w for w in paper_workloads() if w.name == "ATIS-TT")


def _rerank_evidence(measured, analytic, rep, net, opts) -> dict:
    """Did the measured objective perturb the analytic ranking anywhere?

    The order check re-ranks the measured stage-2 candidates under the
    analytic metric directly — the analytic ``SearchResult`` may come
    from the disk winner cache, which records no full candidate order —
    and the re-rank is a stable sort, so an analytic tie is never
    miscounted as a measurement-driven perturbation.
    """
    m_order = [t for _, t in measured.stage2_costs]
    a_order = sorted(m_order, key=lambda t: perf_model.evaluate(
        plan_from_tree(net, t), fused_chain=opts.fused_chain,
        max_chain_len=opts.max_chain_len).metric("latency"))
    return {
        "winner_changed": measured.tree != analytic.tree,
        "nondefault_tiles": rep["nondefault_tiles"],
        "order_changed": m_order != a_order,
    }


def run(print_fn=print, cache_dir: str | None = None) -> list[dict]:
    # A fresh cache dir by default so "cold" is genuinely cold even when
    # the process (or a previous CI step) already tuned these shapes.
    cache_dir = cache_dir or tempfile.mkdtemp(prefix="repro-autotune-bench-")
    wl = _atis()
    net = wl.fact.forward_network(batch_axes=(("b", wl.tokens),))
    m_opts = csse.SearchOptions(objective="measured", fused_chain=True)
    a_opts = csse.SearchOptions(objective="latency", fused_chain=True)

    cold = autotune.Tuner(cache_dir=cache_dir)
    csse.clear_memo()
    t0 = time.perf_counter()
    measured = csse.search(net, m_opts, tuner=cold)
    cold_s = time.perf_counter() - t0
    analytic = csse.search(net, a_opts)

    warm = autotune.Tuner(cache_dir=cache_dir)
    csse.clear_memo()
    t0 = time.perf_counter()
    measured2 = csse.search(net, m_opts, tuner=warm)
    warm_s = time.perf_counter() - t0

    compiled, op_rows = autotune.compare_plan(cold, measured.plan)
    rep = compiled.report()
    ev = _rerank_evidence(measured, analytic, rep, net, m_opts)
    if not (ev["winner_changed"] or ev["nondefault_tiles"] > 0
            or ev["order_changed"]):
        # All three evidence channels agreeing with the analytic ranking
        # is usually a timing-noise coincidence (near-tie candidates, all
        # default tiles winning by luck); one independent re-sample with a
        # fresh tuner decides whether the rerank is genuinely inert.
        retry = autotune.Tuner(
            cache_dir=tempfile.mkdtemp(prefix="repro-autotune-retry-"))
        csse.clear_memo()
        measured_r = csse.search(net, m_opts, tuner=retry)
        compiled_r, _ = autotune.compare_plan(retry, measured_r.plan)
        ev = _rerank_evidence(measured_r, analytic, compiled_r.report(),
                              net, m_opts)
        ev["retried"] = True
    lookups = sum(warm.stats.values())
    rows = [{
        "name": f"autotune/{wl.name}-cold",
        "wall_s": cold_s,
        "fusion_hit_rate": rep["fusion_hit_rate"],
        "shapes_measured": cold.stats["measured"],
        "shapes_skipped": cold.stats["skipped"],
        **ev,
    }, {
        "name": f"autotune/{wl.name}-warm",
        "wall_s": warm_s,
        "fusion_hit_rate": rep["fusion_hit_rate"],
        "shapes_measured": warm.stats["measured"],
        "cache_hit_rate": ((warm.stats["disk_hits"]
                            + warm.stats["memo_hits"]) / lookups
                           if lookups else 1.0),
        "same_winner_as_cold": measured2.tree == measured.tree,
    }]
    print_fn(f"{wl.name}: cold tune {cold_s:.2f}s "
             f"({cold.stats['measured']} shapes), warm {warm_s:.4f}s "
             f"({warm.stats['measured']} re-measured)")
    print_fn(f"winner changed by measurement: {rows[0]['winner_changed']}, "
             f"non-default tiles: {rows[0]['nondefault_tiles']}, "
             f"order changed: {rows[0]['order_changed']}, "
             f"ops: {len(op_rows)}"
             + (" (retried)" if ev.get("retried") else ""))
    return rows


def validate(rows) -> list[str]:
    failures = []
    cold = next(r for r in rows if r["name"].endswith("-cold"))
    warm = next(r for r in rows if r["name"].endswith("-warm"))
    if cold["shapes_measured"] == 0:
        failures.append("cold tune measured nothing")
    if warm["shapes_measured"] != 0:
        failures.append(
            f"warm tune re-measured {warm['shapes_measured']} shapes "
            "(disk cache miss)")
    if not warm["same_winner_as_cold"]:
        failures.append("warm rerank disagrees with cold (cache unstable)")
    if not (cold["winner_changed"] or cold["nondefault_tiles"] > 0
            or cold["order_changed"]):
        failures.append("measured objective changed neither the stage-2 "
                        "winner, nor any tile config, nor the stage-2 "
                        "candidate order (rerank inert after retry)")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
