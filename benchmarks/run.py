"""Benchmark entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark row plus the claim
checks each module asserts.  ``python -m benchmarks.run`` is the command
recorded to bench_output.txt.
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import (bench_compression, bench_csse, bench_dataflow,
                            bench_kernels, bench_phase_paths,
                            bench_tnn_vs_dense)

    all_failures: list[str] = []
    csv_lines: list[str] = ["name,us_per_call,derived"]

    def section(title):
        print(f"\n{'=' * 70}\n{title}\n{'=' * 70}")

    section("Fig.13 — CSSE vs restricted search vs fixed sequences")
    rows = bench_csse.run()
    all_failures += bench_csse.validate(rows)
    for r in rows:
        csv_lines.append(
            f"csse/{r['workload']}/{r['strategy']},{r['latency_us']:.2f},"
            f"flops_red={r['flops_red']:.2f};mem_red={r['mem_red']:.2f}")

    section("Fig.14 — tensorized vs dense training (modeled)")
    rows = bench_tnn_vs_dense.run()
    all_failures += bench_tnn_vs_dense.validate(rows)
    for r in rows:
        csv_lines.append(
            f"tnn_vs_dense/{r['workload']},{r['tnn_lat_us']:.2f},"
            f"speedup={r['speedup']:.2f};energy_red={r['energy_red']:.2f}")

    section("Table II — compression ratios")
    rows = bench_compression.run()
    all_failures += bench_compression.validate(rows)
    for r in rows:
        csv_lines.append(
            f"compression/{r['workload']},0,ratio={r['ratio']:.1f}")

    section("§IV training-phase-specific sequences (FP/BP/WG search)")
    rows = bench_phase_paths.run()
    all_failures += bench_phase_paths.validate(rows)
    for r in rows:
        csv_lines.append(
            f"phase_paths/{r['workload']},{r['searched_us']:.2f},"
            f"speedup_vs_reuse={r['speedup']:.2f}")

    section("§V-B dataflow flexibility — VMEM-resident chaining")
    rows = bench_dataflow.run()
    all_failures += bench_dataflow.validate(rows)
    for r in rows:
        csv_lines.append(
            f"dataflow/{r['workload']},0,bytes_red={r['bytes_red']:.2f}")

    section("Kernel micro-benchmarks")
    rows = bench_kernels.run()
    all_failures += bench_kernels.validate(rows)
    for r in rows:
        csv_lines.append(
            f"kernel/{r['name']},{r['us_per_call']:.2f},{r['derived']}")

    section("CSV")
    for line in csv_lines:
        print(line)

    print("\n" + "=" * 70)
    if all_failures:
        print("CLAIM CHECK FAILURES:")
        for f in all_failures:
            print("  -", f)
        raise SystemExit(1)
    print(f"ALL {len(csv_lines) - 1} benchmark rows emitted; "
          "all paper-claim checks PASS")


if __name__ == "__main__":
    main()
