"""Benchmark entry point — one function per paper table/figure.

Every benchmark module's rows normalise to the shared machine-readable
schema (``benchmarks/schema.py``: name, wall_s, fusion_hit_rate, device,
git_sha, metrics); ``--json-dir`` writes one ``BENCH_<module>.json`` per
module and ``--baseline`` gates wall_s regressions against a checked-in
snapshot.  ``--smoke`` runs only the CPU-cheap modules (plan_compiler,
megakernel, autotune, search, and sharded — the last on a fake 8-device
mesh in a subprocess) — that is CI's bench-smoke job:

  PYTHONPATH=src python -m benchmarks.run --smoke --json-dir bench-out \\
      --baseline benchmarks/baselines/bench_smoke_baseline.json

``python -m benchmarks.run`` (no flags) runs the full suite and prints the
records plus each module's paper-claim checks.
"""

from __future__ import annotations

import argparse
import os

from benchmarks import schema


# ---------------------------------------------------------------------------
# Row -> schema.record adapters (one per module)
# ---------------------------------------------------------------------------


def _csse_records(rows):
    return [schema.make_record(
        f"csse/{r['workload']}/{r['strategy']}", r["latency_us"] * 1e-6,
        flops_red=r["flops_red"], mem_red=r["mem_red"]) for r in rows]


def _tnn_vs_dense_records(rows):
    return [schema.make_record(
        f"tnn_vs_dense/{r['workload']}", r["tnn_lat_us"] * 1e-6,
        speedup=r["speedup"], energy_red=r["energy_red"]) for r in rows]


def _compression_records(rows):
    return [schema.make_record(
        f"compression/{r['workload']}", 0.0, ratio=r["ratio"])
        for r in rows]


def _phase_paths_records(rows):
    return [schema.make_record(
        f"phase_paths/{r['workload']}", r["searched_us"] * 1e-6,
        speedup_vs_reuse=r["speedup"]) for r in rows]


def _dataflow_records(rows):
    return [schema.make_record(
        f"dataflow/{r['workload']}", 0.0, bytes_red=r["bytes_red"])
        for r in rows]


def _kernels_records(rows):
    return [schema.make_record(
        f"kernel/{r['name']}", r["us_per_call"] * 1e-6, derived=r["derived"])
        for r in rows]


def _plan_compiler_records(rows):
    return [schema.make_record(
        f"plan_compiler/{r['workload']}/{r['phase']}", r["compile_s"],
        fusion_hit_rate=r["fusion_rate"], steps=r["steps"], ops=r["ops"],
        gemm=r["gemm"], chain=r["chain"], einsum=r["einsum"],
        vmem_transposes=r["vmem_t"], hbm_transposes=r["hbm_t"])
        for r in rows]


def _flat_records(*named):
    """Adapter for modules whose rows already use schema field names:
    ``named`` fields pass through as record fields, the rest as metrics."""
    fields = ("name", "wall_s", "fusion_hit_rate") + named

    def adapt(rows):
        return [schema.make_record(
            r["name"], r["wall_s"], fusion_hit_rate=r["fusion_hit_rate"],
            **{k: r[k] for k in named},
            **{k: v for k, v in r.items() if k not in fields})
            for r in rows]
    return adapt


_autotune_records = _flat_records()
_megakernel_records = _flat_records("achieved_gbps", "chain_len")
_search_records = _flat_records("measurements")
_sharded_records = _flat_records()
_precision_records = _flat_records("dtype", "policy")
_memory_records = _flat_records("dtype", "policy", "peak_bytes")
_serving_records = _flat_records("dtype", "policy", "peak_bytes",
                                 "p50_ms", "p99_ms", "ttft_ms",
                                 "tok_per_s", "requests")
_telemetry_records = _flat_records()


def _suite(smoke: bool):
    """(title, module_name, records_adapter) per benchmark module.

    Modeled-cost modules (csse, tnn_vs_dense, ...) are skipped under
    ``--smoke``: they are deterministic model evaluations the tier-1 tests
    already cover, and the smoke job gates *wall-clock* behaviour."""
    suite = [
        ("§III plan compiler lowering (fusion / transpose placement)",
         "bench_plan_compiler", _plan_compiler_records),
        ("Megakernel N-step chains: HBM bytes vs chain-length cap + "
         "achieved-vs-attainable roofline (docs/MEGAKERNEL.md)",
         "bench_megakernel", _megakernel_records),
        ("§IV+§VI-C measured autotuning (cold/warm tune + rerank)",
         "bench_autotune", _autotune_records),
        ("Joint cross-layer plan search: measurement budget vs the "
         "exhaustive per-combo pipeline (docs/SEARCH.md)",
         "bench_search", _search_records),
        ("§IV butterfly-analog SPMD: comm-aware vs comm-free CSSE "
         "(fake 8-device mesh)",
         "bench_sharded", _sharded_records),
        ("FP8/INT8 quantized contraction: bytes moved + wall, bf16 vs "
         "fp8 vs int8",
         "bench_precision", _precision_records),
        ("Peak activation memory: plan peaks, budgeted CSSE, stash "
         "policies (store/recompute/quantized)",
         "bench_memory", _memory_records),
        ("Serving: continuous batching under a seeded Poisson trace "
         "(p50/p99/ttft, bf16 vs fp8 KV)",
         "bench_serving", _serving_records),
        ("Telemetry overhead: disabled fast path + <=3% traced slowdown "
         "(docs/OBSERVABILITY.md)",
         "bench_telemetry", _telemetry_records),
    ]
    if not smoke:
        suite = [
            ("Fig.13 — CSSE vs restricted search vs fixed sequences",
             "bench_csse", _csse_records),
            ("Fig.14 — tensorized vs dense training (modeled)",
             "bench_tnn_vs_dense", _tnn_vs_dense_records),
            ("Table II — compression ratios",
             "bench_compression", _compression_records),
            ("§IV training-phase-specific sequences (FP/BP/WG search)",
             "bench_phase_paths", _phase_paths_records),
            ("§V-B dataflow flexibility — VMEM-resident chaining",
             "bench_dataflow", _dataflow_records),
            ("Kernel micro-benchmarks",
             "bench_kernels", _kernels_records),
        ] + suite
    return suite


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-cheap subset (plan_compiler + megakernel + "
                         "autotune + search + sharded + precision + "
                         "memory + serving) — CI's bench-smoke job")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<module>.json files here")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (all modules merged) to gate "
                         "wall_s regressions against")
    ap.add_argument("--gate", type=float, default=1.5,
                    help="fail when wall_s exceeds gate x baseline "
                         "(default 1.5)")
    ap.add_argument("--write-baseline", default=None,
                    help="write all records (merged) as a new baseline "
                         "JSON — how benchmarks/baselines/*.json are "
                         "refreshed")
    ap.add_argument("--summary", default=None, metavar="PATH",
                    help="append the wall_s/peak_bytes delta table "
                         "(markdown) here; defaults to "
                         "$GITHUB_STEP_SUMMARY when set, so CI renders "
                         "the per-benchmark deltas without artifact "
                         "downloads")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a telemetry trace of the whole suite "
                         "('*.jsonl' streams events, other suffixes "
                         "write Chrome trace-event JSON; render with "
                         "python -m repro.analysis.trace_report)")
    args = ap.parse_args(argv)

    from repro import telemetry as tm
    owns_trace = bool(args.trace) and not tm.enabled()
    if owns_trace:
        tm.configure(args.trace)

    import importlib

    all_failures: list[str] = []
    all_records: list[dict] = []

    for title, mod_name, adapt in _suite(args.smoke):
        print(f"\n{'=' * 70}\n{title}\n{'=' * 70}")
        mod = importlib.import_module(f"benchmarks.{mod_name}")
        rows = mod.run()
        all_failures += [f"{mod_name}: {f}" for f in mod.validate(rows)]
        records = adapt(rows)
        all_records += records
        if args.json_dir:
            path = os.path.join(args.json_dir,
                                f"BENCH_{mod_name.removeprefix('bench_')}"
                                ".json")
            schema.write_json(path, records)
            print(f"wrote {path} ({len(records)} records)")

    print(f"\n{'=' * 70}\nrecords\n{'=' * 70}")
    for r in all_records:
        fh = ("-" if r["fusion_hit_rate"] is None
              else f"{r['fusion_hit_rate']:.0%}")
        print(f"{r['name']:45s} wall={r['wall_s']:.6f}s fused={fh} "
              f"[{r['device']} @ {r['git_sha']}]")

    if args.write_baseline:
        schema.write_json(args.write_baseline, all_records)
        print(f"\nwrote baseline {args.write_baseline} "
              f"({len(all_records)} records)")

    if args.baseline:
        baseline = schema.load_json(args.baseline)
        gate_failures = schema.regression_failures(
            all_records, baseline, gate=args.gate)
        all_failures += [f"regression: {f}" for f in gate_failures]
        print(f"\nregression gate: {len(baseline)} baseline records, "
              f"gate {args.gate}x (wall_s + peak_bytes) -> "
              f"{'PASS' if not gate_failures else 'FAIL'}")
        summary = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
        if summary:
            with open(summary, "a") as f:
                f.write("## bench-smoke deltas vs baseline\n\n")
                f.write(schema.delta_table(all_records, baseline))
                f.write(f"\n\ngate {args.gate}x: "
                        f"{'PASS' if not gate_failures else 'FAIL'}\n")
            print(f"wrote delta table to {summary}")

    if owns_trace:
        tm.finalize()
        print(f"\nwrote telemetry trace {args.trace}")

    print("\n" + "=" * 70)
    if all_failures:
        print("CLAIM CHECK FAILURES:")
        for f in all_failures:
            print("  -", f)
        raise SystemExit(1)
    print(f"ALL {len(all_records)} benchmark records emitted; "
          "all paper-claim checks PASS")


if __name__ == "__main__":
    main()
