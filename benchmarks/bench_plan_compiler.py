"""Plan-compiler lowering statistics: einsum steps vs compiled kernel ops.

For every paper workload, compile the FP, BP and the fixed left-deep FP
plans and report what the lowering actually did: how many einsum steps
became MXU GEMMs, how many adjacent pairs fused into a single
``chain_pallas`` call (intermediate VMEM-resident — what CSSE stage-2
models as ``fused_chain=True``), how many layout flips were absorbed into
the kernel's VMEM stage (``transpose_rhs``) versus materialised in HBM,
and how many steps fell back to einsum (hyperedges / batch residuals).
"""

from __future__ import annotations

import time

from repro.core import csse, plan_compiler
from repro.core.tensorized import _bp_network
from repro.core.tnetwork import plan_from_tree

from benchmarks.workloads import paper_workloads

_OPTS = csse.SearchOptions(objective="edp", fused_chain=True)


def _plans(wl):
    fp_net = wl.fact.forward_network(batch_axes=(("b", wl.tokens),))
    yield "fp", csse.search(fp_net, _OPTS).plan
    yield "bp", csse.search(_bp_network(wl.fact, wl.tokens), _OPTS).plan
    # The prior-work left-deep chain: sequential X·G·G·... — the shape the
    # chain fusion pass is built for.
    yield "fp-fixed", plan_from_tree(fp_net, wl.fact.fixed_tree(fp_net))


def run(print_fn=print) -> list[dict]:
    rows = []
    for wl in paper_workloads():
        for phase, plan in _plans(wl):
            t0 = time.perf_counter()
            rep = plan_compiler.compile_plan(plan).report()
            compile_s = time.perf_counter() - t0
            rows.append({
                "workload": wl.name, "phase": phase,
                "compile_s": compile_s,
                "steps": rep["num_steps"], "ops": rep["num_ops"],
                "gemm": rep["num_gemm"], "chain": rep["num_chain"],
                "einsum": rep["num_einsum_fallback"],
                "fusion_rate": rep["fusion_hit_rate"],
                "vmem_t": rep["vmem_transposes"],
                "hbm_t": rep["hbm_transposes"],
            })
    print_fn(f"{'workload':10s} {'phase':9s} {'steps':>5s} {'ops':>4s} "
             f"{'gemm':>4s} {'chain':>5s} {'einsum':>6s} {'fused%':>7s} "
             f"{'vmemT':>5s} {'hbmT':>4s}")
    for r in rows:
        print_fn(f"{r['workload']:10s} {r['phase']:9s} {r['steps']:5d} "
                 f"{r['ops']:4d} {r['gemm']:4d} {r['chain']:5d} "
                 f"{r['einsum']:6d} {r['fusion_rate']:7.0%} "
                 f"{r['vmem_t']:5d} {r['hbm_t']:4d}")
    total_steps = sum(r["steps"] for r in rows)
    fused_steps = sum(2 * r["chain"] for r in rows)
    print_fn(f"overall fusion hit-rate: {fused_steps}/{total_steps} steps "
             f"({fused_steps / max(total_steps, 1):.0%})")
    return rows


def validate(rows) -> list[str]:
    """Structural claims the compiled lowering must satisfy."""
    failures = []
    for r in rows:
        # Fusion can only shrink the op list: ops = steps - chains.
        if r["ops"] != r["steps"] - r["chain"]:
            failures.append(f"{r['workload']}/{r['phase']}: op count "
                            f"{r['ops']} != steps - chains")
        if r["gemm"] + 2 * r["chain"] + r["einsum"] != r["steps"]:
            failures.append(f"{r['workload']}/{r['phase']}: step accounting "
                            "mismatch")
    # The left-deep TT chains must demonstrate real chain fusion somewhere.
    tt_fixed = [r for r in rows
                if r["phase"] == "fp-fixed" and "TT" in r["workload"]]
    if not any(r["chain"] >= 1 for r in tt_fixed):
        failures.append("no TT left-deep plan fused a chain_pallas pair")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
