"""Fig. 13 reproduction: CSSE vs restricted search vs fixed sequences.

For each paper workload, compare four strategies on the FP network:
  * fixed       — the hard-coded ascending sequence (TIE/ETTE/FDHT)
  * tetrix      — input-anchored restricted search (Tetrix's space)
  * csse-flops  — stage-1 winner (FLOPs metric)
  * csse-model  — two-stage winner (EDP under the TPU perf model)

Reported per strategy: FLOPs reduction over dense, memory-access reduction
over dense, arithmetic intensity vs dense, modeled latency and energy —
the five panels of Fig. 13.
"""

from __future__ import annotations

from repro.core import csse, perf_model
from repro.core.tnetwork import TensorNetwork, plan_from_tree

from benchmarks.workloads import paper_workloads


def dense_cost(wl, hw=perf_model.TPU_V5E):
    """The uncompressed layer: one [tokens, N] x [N, M] matmul."""
    fact = wl.fact
    net = TensorNetwork(
        sizes={"b": wl.tokens, "n": fact.N, "m": fact.M},
        nodes=(("b", "n"), ("m", "n")),
        node_names=("X", "W"),
        output=("b", "m"))
    plan = plan_from_tree(net, (0, 1))
    return plan, perf_model.evaluate(plan, hw)


def strategies(wl):
    net = wl.fact.forward_network(batch_axes=(("b", wl.tokens),))
    yield "fixed", csse.fixed_plan(net, wl.fact.fixed_tree(net))
    yield "tetrix", csse.search(net, csse.SearchOptions(
        objective="edp", anchor_input=True, allow_outer=False))
    yield "csse-flops", csse.search(net, csse.SearchOptions(objective="flops"))
    yield "csse-model", csse.search(net, csse.SearchOptions(objective="edp"))


def run(print_fn=print) -> list[dict]:
    rows = []
    for wl in paper_workloads():
        dplan, dcost = dense_cost(wl)
        for name, res in strategies(wl):
            c = res.cost
            rows.append({
                "workload": wl.name, "strategy": name,
                "flops_red": dplan.total_flops / max(res.plan.total_flops, 1),
                "mem_red": dcost.bytes_hbm / max(c.bytes_hbm, 1),
                "ai_vs_dense": (c.arithmetic_intensity
                                / max(dcost.arithmetic_intensity, 1e-9)),
                "latency_us": c.latency_s * 1e6,
                "energy_uj": c.energy_j * 1e6,
                "edp": c.edp,
            })
    print_fn(f"{'workload':10s} {'strategy':11s} {'FLOPsRed':>9s} "
             f"{'MemRed':>8s} {'AI':>6s} {'lat_us':>8s} {'E_uJ':>8s}")
    for r in rows:
        print_fn(f"{r['workload']:10s} {r['strategy']:11s} "
                 f"{r['flops_red']:9.2f} {r['mem_red']:8.2f} "
                 f"{r['ai_vs_dense']:6.2f} {r['latency_us']:8.1f} "
                 f"{r['energy_uj']:8.1f}")
    return rows


def validate(rows) -> list[str]:
    """The paper's directional claims this benchmark must reproduce."""
    failures = []
    by = {(r["workload"], r["strategy"]): r for r in rows}
    for wl in {r["workload"] for r in rows}:
        model = by[(wl, "csse-model")]
        flops = by[(wl, "csse-flops")]
        tetrix = by[(wl, "tetrix")]
        fixed = by[(wl, "fixed")]
        # CSSE never loses to the restricted/fixed baselines on EDP.
        if model["edp"] > tetrix["edp"] * 1.0001:
            failures.append(f"{wl}: csse-model EDP worse than tetrix")
        if model["edp"] > fixed["edp"] * 1.0001:
            failures.append(f"{wl}: csse-model EDP worse than fixed")
        # stage-1 never loses on raw FLOPs.
        if flops["flops_red"] < tetrix["flops_red"] * 0.9999:
            failures.append(f"{wl}: csse-flops worse than tetrix on FLOPs")
    return failures


if __name__ == "__main__":
    failures = validate(run())
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
