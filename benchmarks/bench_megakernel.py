"""Megakernel N-step chains: HBM bytes, wall clock, and the roofline.

The megakernel acceptance claim (docs/MEGAKERNEL.md): on the ATIS-TT
forward phase's left-deep plan, a 3+-step on-chip chain moves strictly
fewer HBM bytes than the pairwise (``max_chain_len=2``) lowering — in
*both* accountings: the perf model's plan-level bytes
(``perf_model.evaluate``) and the compiled plan's own kernel-dispatch
traffic (``CompiledPlan.hbm_bytes``, chains charging only their boundary
tensors).  Every cap also runs the compiled plan against the einsum
reference (the differential harness's smoke-sized twin) and reports the
:class:`repro.analysis.roofline.PhaseRoofline` achieved-vs-attainable
numbers; the smoke gate then watches ``fusion_hit_rate`` (exact drop)
and ``achieved_gbps`` (inverted bandwidth gate) per record.

Nightly sweeps the full chain-length range:

    PYTHONPATH=src python -m benchmarks.bench_megakernel \\
        --chain-lens 2,3,4,5
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.analysis.roofline import PhaseRoofline
from repro.core import contraction, factorizations as F, perf_model
from repro.core import plan_compiler
from repro.core.csse import plan_from_tree

TOKENS = 128
DEFAULT_CHAIN_LENS = (2, 3, 4)


def _workload():
    """ATIS-TT (benchmarks/workloads.py dims) forward phase, left-deep
    fixed tree — the shape the chain lowering is built for."""
    fact = F.tt((12, 8, 8), (8, 8, 12), 8)
    net = fact.forward_network(batch_axes=(("b", TOKENS),))
    plan = plan_from_tree(net, fact.fixed_tree(net))
    key = jax.random.PRNGKey(0)
    tensors = []
    for i in range(net.num_nodes):
        key, sub = jax.random.split(key)
        tensors.append(jax.random.normal(sub, net.node_shape(i),
                                         jnp.float32))
    return plan, tensors


def _timed(fn, *args, iters=3):
    out = jax.block_until_ready(fn(*args))          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return out, (time.perf_counter() - t0) / iters


def run(print_fn=print, chain_lens=DEFAULT_CHAIN_LENS) -> list[dict]:
    plan, tensors = _workload()
    want = contraction.execute(plan, tensors, backend="einsum")
    rows = []
    for cap in chain_lens:
        compiled = plan_compiler.compile_plan(plan, fuse=True,
                                              max_chain_len=cap)
        rep = compiled.report()
        cost = perf_model.evaluate(plan, fused_chain=True,
                                   max_chain_len=cap)
        fn = jax.jit(lambda ts, c=compiled: plan_compiler.run(c, ts))
        got, wall_s = _timed(fn, tensors)
        err = float(jnp.max(jnp.abs(got - want))
                    / jnp.maximum(jnp.max(jnp.abs(want)), 1e-30))
        lowered = compiled.hbm_bytes()
        roof = PhaseRoofline(phase="fp-fixed", flops=float(cost.flops),
                             hbm_bytes=float(lowered), wall_s=wall_s,
                             chain_len=rep["max_chain_len_emitted"])
        rows.append({
            "name": f"megakernel/atis-tt/fp-fixed/L{cap}",
            "wall_s": wall_s,
            "fusion_hit_rate": rep["fusion_hit_rate"],
            "achieved_gbps": roof.achieved_gbps,
            "chain_len": rep["max_chain_len_emitted"],
            "cap": cap,
            "num_chain": rep["num_chain"],
            "lowered_hbm_bytes": lowered,
            "modeled_hbm_bytes": int(cost.bytes_hbm * 4),
            "attainable_s": roof.attainable_s,
            "efficiency": roof.efficiency,
            "rel_err": err,
        })
    print_fn(f"{'cap':>3s} {'emitted':>7s} {'fused%':>6s} "
             f"{'lowered_B':>10s} {'modeled_B':>10s} {'wall_ms':>8s} "
             f"{'GB/s':>8s} {'rel_err':>8s}")
    for r in rows:
        print_fn(f"{r['cap']:3d} {r['chain_len']:7d} "
                 f"{r['fusion_hit_rate']:6.0%} "
                 f"{r['lowered_hbm_bytes']:10d} "
                 f"{r['modeled_hbm_bytes']:10d} "
                 f"{r['wall_s'] * 1e3:8.2f} {r['achieved_gbps']:8.3f} "
                 f"{r['rel_err']:8.1e}")
    return rows


def validate(rows) -> list[str]:
    """The megakernel acceptance claims."""
    failures = []
    for r in rows:
        if r["rel_err"] > 1e-5:
            failures.append(f"{r['name']}: compiled plan diverged from the "
                            f"einsum reference (rel {r['rel_err']:.1e})")
    by_cap = {r["cap"]: r for r in rows}
    pair = by_cap.get(2)
    deep = [r for r in rows if r["chain_len"] >= 3]
    if pair is None:
        failures.append("no pairwise (cap 2) baseline row emitted")
    elif not deep:
        failures.append("no cap emitted a 3+-step chain — megakernel "
                        "lowering never engaged")
    else:
        if not any(r["lowered_hbm_bytes"] < pair["lowered_hbm_bytes"]
                   for r in deep):
            failures.append("no 3+-step chain reduced lowered HBM bytes "
                            "vs the pairwise baseline")
        if not any(r["modeled_hbm_bytes"] < pair["modeled_hbm_bytes"]
                   for r in deep):
            failures.append("no 3+-step chain reduced modeled HBM bytes "
                            "vs the pairwise baseline")
    return failures


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--chain-lens", default=None,
                    help="comma-separated chain-length caps to sweep "
                         "(nightly: 2,3,4,5; default 2,3,4)")
    args = ap.parse_args()
    lens = (DEFAULT_CHAIN_LENS if args.chain_lens is None
            else tuple(int(v) for v in args.chain_lens.split(",")))
    failures = validate(run(chain_lens=lens))
    print("\nclaim checks:", "ALL PASS" if not failures else failures)
    raise SystemExit(1 if failures else 0)
