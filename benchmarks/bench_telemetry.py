"""Telemetry overhead benchmark: tracing must be (nearly) free.

The tracer's contract (docs/OBSERVABILITY.md) is two-sided:

* **Disabled** — every instrumented call site is one attribute load and
  a falsy check.  Measured here as nanoseconds per ``tm.span`` +
  ``tm.inc`` pair, gated at an absolute bound loose enough for CI's
  shared runners but tight enough that an accidental dict build or
  clock read on the disabled path fails the suite.
* **Enabled** — a full in-memory trace of ``bench_plan_compiler.run``
  (the suite's densest span emitter: CSSE spans, compile spans,
  counters) must not slow it by more than ``OVERHEAD_GATE`` (3%), with
  an absolute floor of ``ABS_FLOOR_S`` so sub-millisecond jitter on a
  fast run cannot fail the ratio.

Both sides use min-of-``REPEATS`` walls (min is the standard
noise-rejecting estimator for cold-cache-free repeat timing), and the
enabled/disabled runs alternate so drift in machine load hits both
arms equally.
"""

from __future__ import annotations

import time

from repro import telemetry as tm

from benchmarks import bench_plan_compiler

REPEATS = 3
OVERHEAD_GATE = 1.03         # enabled wall <= 3% over disabled wall
ABS_FLOOR_S = 0.050          # ratio only gates above this disabled wall
DISABLED_NS_BOUND = 2000.0   # ns per disabled span+inc pair (CI-loose)
_CALLS = 100_000


_silent = lambda *a, **k: None  # noqa: E731


def _disabled_ns_per_call() -> float:
    """ns per (span + inc) pair with the tracer disabled."""
    assert not tm.enabled()
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        for _ in range(_CALLS):
            with tm.span("bench.noop"):
                pass
            tm.inc("bench.noop")
        best = min(best, time.perf_counter() - t0)
    return best / _CALLS * 1e9


def _wall_once() -> float:
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        bench_plan_compiler.run(print_fn=_silent)
        best = min(best, time.perf_counter() - t0)
    return best


def _wall_disabled() -> float:
    with tm.suspended():
        return _wall_once()


def _wall_enabled(external: bool) -> float:
    if external:
        # Suite runs under --trace: the tracer is already on; the extra
        # spans land in the caller's trace, which is fine.
        return _wall_once()
    tm.configure()               # in-memory trace, no output file
    try:
        return _wall_once()
    finally:
        tm.reset()


def run(print_fn=print, smoke: bool = True) -> list[dict]:
    external = tm.enabled()
    with tm.suspended():
        ns = _disabled_ns_per_call()
    # Alternate the arms so load drift is shared: off, on, off, on ...
    wall_off = _wall_disabled()
    wall_on = _wall_enabled(external)
    wall_off = min(wall_off, _wall_disabled())
    wall_on = min(wall_on, _wall_enabled(external))
    ratio = wall_on / wall_off if wall_off > 0 else 1.0
    rows = [{
        "name": "telemetry/overhead/plan_compiler",
        "wall_s": wall_off,
        "fusion_hit_rate": None,
        "traced_wall_s": wall_on,
        "overhead_ratio": ratio,
        "disabled_ns_per_call": ns,
    }]
    print_fn(f"disabled span+inc: {ns:.0f} ns/call "
             f"(bound {DISABLED_NS_BOUND:.0f})")
    print_fn(f"plan_compiler wall: off={wall_off*1e3:.1f}ms "
             f"on={wall_on*1e3:.1f}ms ratio={ratio:.3f} "
             f"(gate {OVERHEAD_GATE:.2f}x above "
             f"{ABS_FLOOR_S*1e3:.0f}ms)")
    return rows


def validate(rows) -> list[str]:
    failures = []
    for r in rows:
        if r["disabled_ns_per_call"] > DISABLED_NS_BOUND:
            failures.append(
                f"{r['name']}: disabled tracer costs "
                f"{r['disabled_ns_per_call']:.0f} ns/call "
                f"> {DISABLED_NS_BOUND:.0f} (the no-op fast path grew "
                f"real work)")
        if (r["wall_s"] >= ABS_FLOOR_S
                and r["overhead_ratio"] > OVERHEAD_GATE):
            failures.append(
                f"{r['name']}: enabled tracing slows the workload "
                f"{r['overhead_ratio']:.3f}x > {OVERHEAD_GATE}x "
                f"({r['wall_s']*1e3:.1f}ms -> "
                f"{r['traced_wall_s']*1e3:.1f}ms)")
    return failures


if __name__ == "__main__":
    for row in run(smoke=True):
        print(row)
    errs = validate(run(print_fn=lambda *_: None, smoke=True))
    for e in errs:
        print("FAIL:", e)
    raise SystemExit(1 if errs else 0)
